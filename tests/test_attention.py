"""Attention correctness: blockwise-vs-naive oracle, GQA grouping,
sliding window, decode-cache ≡ prefill consistency, MLA absorbed decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import attention, transformer
from repro.models.config import LayerSpec, MLAConfig, ModelConfig


def naive_attn(q, k, v, causal=True, window=0):
    """O(T²) oracle with GQA grouping."""
    B, T, H, D = q.shape
    S, Hkv = k.shape[1], k.shape[2]
    G = H // Hkv
    qg = q.reshape(B, T, Hkv, G, D).astype(jnp.float32) * D ** -0.5
    s = jnp.einsum("bqhgd,bkhd->bqhgk", qg, k.astype(jnp.float32))
    i, j = jnp.arange(T)[:, None], jnp.arange(S)[None, :]
    mask = jnp.ones((T, S), bool)
    if causal:
        mask &= j <= i
    if window:
        mask &= i - j < window
    s = jnp.where(mask[None, :, None, None, :], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bqhgk,bkhd->bqhgd", p, v.astype(jnp.float32))
    return o.reshape(B, T, H, -1)


@pytest.mark.parametrize("T,H,Hkv,D", [(32, 4, 2, 16), (65, 8, 1, 8),
                                       (128, 4, 4, 32)])
@pytest.mark.parametrize("window", [0, 16])
@pytest.mark.parametrize("seed", [0, 1])
def test_blockwise_matches_naive(T, H, Hkv, D, window, seed):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (2, T, H, D))
    k = jax.random.normal(ks[1], (2, T, Hkv, D))
    v = jax.random.normal(ks[2], (2, T, Hkv, D))
    ref = naive_attn(q, k, v, window=window)
    out = attention._blockwise_attn(q, k, v, window=window,
                                    q_block=16, kv_block=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-4, atol=2e-5)


def test_blockwise_block_size_invariance():
    ks = jax.random.split(jax.random.PRNGKey(7), 3)
    q = jax.random.normal(ks[0], (1, 48, 4, 8))
    k = jax.random.normal(ks[1], (1, 48, 2, 8))
    v = jax.random.normal(ks[2], (1, 48, 2, 8))
    a = attention._blockwise_attn(q, k, v, q_block=8, kv_block=8)
    b = attention._blockwise_attn(q, k, v, q_block=48, kv_block=48)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=2e-4,
                               atol=2e-5)


def _gqa_cfg(window=0):
    return ModelConfig(
        name="t", n_layers=1, d_model=64, n_heads=4, n_kv_heads=2,
        d_ff=128, vocab=97, qk_norm=True, window=window,
        segments=((1, (LayerSpec(),)),))


@pytest.mark.parametrize("window", [0, 8])
def test_gqa_decode_matches_prefill(window):
    """Prefill T tokens via gqa_apply ≡ decoding them one at a time."""
    cfg = _gqa_cfg(window)
    p = attention.gqa_init(jax.random.PRNGKey(0), cfg)
    p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
    T = 12
    x = jax.random.normal(jax.random.PRNGKey(1), (2, T, 64))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (2, T))
    full = attention.gqa_apply(p, x, pos, cfg, window=window)

    cache = attention.gqa_init_cache(cfg, 2, T, window)
    cache = jax.tree.map(lambda a: a.astype(jnp.float32)
                         if a.dtype == jnp.bfloat16 else a, cache)
    outs = []
    for t in range(T):
        y, cache = attention.gqa_decode(p, x[:, t:t + 1], cache, cfg,
                                        window=window)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_sliding_window_cache_is_bounded():
    cfg = _gqa_cfg(window=4)
    cache = attention.gqa_init_cache(cfg, 2, max_len=100, window=4)
    assert cache.k.shape[1] == 4      # ring buffer, not max_len


@pytest.mark.parametrize("window", [0, 8])
def test_quantized_kv_cache_close_to_full_precision(window):
    """int8 KV cache (§Perf serving optimization) tracks the bf16 path."""
    cfg = _gqa_cfg(window)
    p = jax.tree.map(lambda a: a.astype(jnp.float32),
                     attention.gqa_init(jax.random.PRNGKey(0), cfg))
    T = 12
    x = jax.random.normal(jax.random.PRNGKey(1), (2, T, 64))
    c_full = jax.tree.map(lambda a: a.astype(jnp.float32)
                          if a.dtype == jnp.bfloat16 else a,
                          attention.gqa_init_cache(cfg, 2, T, window))
    c_q = attention.gqa_init_cache(cfg, 2, T, window, quantized=True)
    assert c_q.k_q.dtype == jnp.int8
    of, oq = [], []
    for t in range(T):
        yf, c_full = attention.gqa_decode(p, x[:, t:t + 1], c_full, cfg,
                                          window=window)
        yq, c_q = attention.gqa_decode(p, x[:, t:t + 1], c_q, cfg,
                                       window=window)
        of.append(yf)
        oq.append(yq)
    of = jnp.concatenate(of, 1)
    oq = jnp.concatenate(oq, 1)
    rel = float(jnp.abs(of - oq).max() / (jnp.abs(of).max() + 1e-9))
    assert rel < 0.05, rel


def _mla_cfg():
    return ModelConfig(
        name="t", n_layers=1, d_model=64, n_heads=4, n_kv_heads=4,
        d_ff=128, vocab=97, attn_kind="mla",
        mla=MLAConfig(q_lora=32, kv_lora=16, d_nope=16, d_rope=8, d_v=16),
        segments=((1, (LayerSpec(),)),))


def test_mla_absorbed_decode_matches_prefill():
    cfg = _mla_cfg()
    p = attention.mla_init(jax.random.PRNGKey(0), cfg)
    p = jax.tree.map(lambda a: a.astype(jnp.float32), p)
    T = 10
    x = jax.random.normal(jax.random.PRNGKey(1), (2, T, 64))
    pos = jnp.broadcast_to(jnp.arange(T)[None], (2, T))
    full = attention.mla_apply(p, x, pos, cfg)

    cache = attention.mla_init_cache(cfg, 2, T)
    cache = jax.tree.map(lambda a: a.astype(jnp.float32)
                         if a.dtype == jnp.bfloat16 else a, cache)
    outs = []
    for t in range(T):
        y, cache = attention.mla_decode(p, x[:, t:t + 1], cache, cfg)
        outs.append(y)
    dec = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full),
                               rtol=2e-3, atol=2e-3)


def test_mla_cache_is_compressed():
    """The MLA cache stores kv_lora + d_rope per token, not H·(K+V)."""
    cfg = _mla_cfg()
    cache = attention.mla_init_cache(cfg, 1, 100)
    per_tok = cache.c_kv.shape[-1] + cache.k_rope.shape[-1]
    full_kv = cfg.n_heads * (cfg.mla.d_nope + cfg.mla.d_rope
                             + cfg.mla.d_v)
    assert per_tok < full_kv / 4
