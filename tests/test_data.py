"""Synthetic data + Dirichlet partitioning tests."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data import partition, synthetic


@pytest.mark.parametrize("name", synthetic.DATASETS)
def test_make_dataset_contract(name):
    x, y, cfg = synthetic.make_dataset(name, 500, jax.random.PRNGKey(0),
                                       side=10)
    assert x.shape == (500, 100)
    assert x.dtype == jnp.uint8
    assert set(np.unique(np.asarray(x))) <= {0, 1}
    assert int(y.max()) < cfg.n_classes


def test_dataset_is_learnable_signal():
    """Samples of the same class are closer than cross-class (on average)."""
    x, y, cfg = synthetic.make_dataset("synthmnist", 600,
                                       jax.random.PRNGKey(1), side=10)
    x = np.asarray(x, dtype=np.float32)
    y = np.asarray(y)
    same, diff = [], []
    for c in range(3):
        a = x[y == c][:20]
        b = x[y == (c + 1) % cfg.n_classes][:20]
        if len(a) < 2 or len(b) < 1:
            continue
        same.append(np.abs(a[:10, None] - a[None, 10:20]).mean())
        diff.append(np.abs(a[:10, None] - b[None, :10]).mean())
    assert np.mean(same) < np.mean(diff)


def test_partition_shapes_and_determinism():
    x, y, cfg = synthetic.make_dataset("synthmnist", 800,
                                       jax.random.PRNGKey(0), side=10)
    kw = dict(n_clients=6, experiment=3, key=jax.random.PRNGKey(5),
              n_train=30, n_test=10, n_conf=10)
    a = partition.partition(x, y, cfg.n_classes, **kw)
    b = partition.partition(x, y, cfg.n_classes, **kw)
    assert a.x_train.shape == (6, 30, 100)
    assert a.x_conf.shape == (6, 10, 100)
    assert (a.y_train == b.y_train).all()          # deterministic


def test_experiment1_uniform_vs_experiment5_skewed():
    x, y, cfg = synthetic.make_dataset("synthmnist", 3000,
                                       jax.random.PRNGKey(0), side=10)

    def entropy(exp):
        cd = partition.partition(x, y, cfg.n_classes, n_clients=8,
                                 experiment=exp, key=jax.random.PRNGKey(1),
                                 n_train=100, n_test=10, n_conf=10)
        ents = []
        for i in range(8):
            counts = np.bincount(np.asarray(cd.y_train[i]),
                                 minlength=cfg.n_classes)
            p = counts / counts.sum()
            ents.append(-(p[p > 0] * np.log(p[p > 0])).sum())
        return np.mean(ents)

    assert entropy(1) > entropy(5) + 0.5


def test_experiment_mix_fraction():
    """Experiment 3 = 50% IID / 50% non-IID clients (paper Fig. 3)."""
    mix = partition.client_mixtures(8, 10, 0.5, jax.random.PRNGKey(0))
    maxp = np.asarray(mix.max(axis=1))
    # IID half near-uniform (max prob ≈ 0.1), non-IID half spiked
    assert (maxp[:4] < 0.25).all()
    assert (maxp[4:] > 0.5).all()


def test_labels_match_mixture():
    x, y, cfg = synthetic.make_dataset("synthmnist", 2000,
                                       jax.random.PRNGKey(0), side=10)
    cd = partition.partition(x, y, cfg.n_classes, n_clients=4, experiment=5,
                             key=jax.random.PRNGKey(2), n_train=200,
                             n_test=10, n_conf=10)
    for i in range(4):
        top_mix = int(jnp.argmax(cd.mixtures[i]))
        counts = np.bincount(np.asarray(cd.y_train[i]), minlength=10)
        assert counts[top_mix] >= 0.4 * counts.sum()


def test_booleanize():
    f = jnp.array([[0.2, 0.7], [0.5, 0.4]])
    assert (synthetic.booleanize(f) == jnp.array([[0, 1], [1, 0]])).all()
    u8 = jnp.array([[10, 200]], dtype=jnp.uint8)
    assert (synthetic.booleanize(u8) == jnp.array([[0, 1]])).all()
    b = jnp.array([[0, 1]], dtype=jnp.uint8)
    assert (synthetic.booleanize(b) == b).all()
