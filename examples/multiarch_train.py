"""Walk all 10 assigned architectures (reduced variants) through a short
training run each — the `--arch` selectable-config surface in one script.

  PYTHONPATH=src python examples/multiarch_train.py [--steps 3]
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.launch import steps as steps_mod
from repro.models import config as mcfg
from repro.models import stubs, transformer
from repro.optim import adamw


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=3)
    args = ap.parse_args()

    for arch in registry.ARCHS:
        cfg = mcfg.reduced(registry.get(arch))
        key = jax.random.PRNGKey(0)
        params = transformer.init(key, cfg)
        opt_cfg = adamw.AdamWConfig(lr=1e-3)
        opt = adamw.init(params, opt_cfg)
        step = jax.jit(steps_mod.make_train_step(cfg, opt_cfg))
        toks = stubs.tokens_for(cfg, jax.random.PRNGKey(1), 2, 32)
        batch = {"tokens": toks, "labels": jnp.roll(toks, -1, axis=1)}
        t0 = time.time()
        losses = []
        for _ in range(args.steps):
            params, opt, m = step(params, opt, batch)
            losses.append(round(float(m["loss"]), 3))
        print(f"{arch:24s} losses={losses}  ({time.time()-t0:.1f}s)",
              flush=True)


if __name__ == "__main__":
    main()
