"""Serve a small model with batched requests: prefill via the parallel
forward, then batched greedy decode through the unified cache protocol
(GQA ring-buffer / MLA latent / SSM state caches all behind one API).

  PYTHONPATH=src python examples/serve_decode.py --arch deepseek-v3-671b
  PYTHONPATH=src python examples/serve_decode.py --arch xlstm-350m
"""
import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import config as mcfg
from repro.models import stubs, transformer


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="yi-6b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--decode-steps", type=int, default=24)
    args = ap.parse_args()

    cfg = mcfg.reduced(registry.get(args.arch))
    print(f"serving {cfg.name}: {len(cfg.layer_list())} layers, "
          f"d_model={cfg.d_model}, batched requests={args.batch}")
    params = transformer.init(jax.random.PRNGKey(0), cfg)

    prompts = stubs.tokens_for(cfg, jax.random.PRNGKey(1), args.batch,
                               args.prompt_len)
    max_len = args.prompt_len + args.decode_steps
    caches = transformer.init_cache(cfg, args.batch, max_len)

    # prefill: parallel forward for logits; decode path fills the cache
    t0 = time.time()
    logits, _ = jax.jit(lambda p, t: transformer.forward(
        p, cfg, tokens=t, remat=False))(params, prompts)
    for t in range(args.prompt_len):
        _, caches = transformer.decode_step(params, cfg,
                                            prompts[:, t:t + 1], caches)
    print(f"prefill({args.prompt_len} tok × {args.batch} req): "
          f"{time.time()-t0:.2f}s")

    step = jax.jit(lambda p, t, c: transformer.decode_step(p, cfg, t, c))
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.time()
    gen = [tok]
    for _ in range(args.decode_steps):
        lg, caches = step(params, tok, caches)
        tok = jnp.argmax(lg, axis=-1).astype(jnp.int32)
        gen.append(tok)
    dt = time.time() - t0
    print(f"decode: {args.decode_steps} steps × {args.batch} requests "
          f"in {dt:.2f}s → {args.decode_steps*args.batch/dt:.1f} tok/s")
    print("request 0 tokens:", jnp.concatenate(gen, 1)[0, :12].tolist())


if __name__ == "__main__":
    main()
