"""Quickstart: train one Tsetlin Machine client, inspect its confidence,
then run a 5-client TPFL mini-federation.

  PYTHONPATH=src python examples/quickstart.py
"""
import jax
import jax.numpy as jnp

from repro.core import federation, tm
from repro.data import partition, synthetic


def main() -> None:
    key = jax.random.PRNGKey(0)

    # --- 1. a single TM client ------------------------------------------
    x, y, dcfg = synthetic.make_dataset("synthmnist", 2000, key, side=12)
    tm_cfg = tm.TMConfig(n_classes=10, n_clauses=50,
                         n_features=dcfg.n_features, s=5.0, T=30)
    params = tm.init_params(tm_cfg, key)
    params = tm.train(params, x[:300], y[:300], jax.random.PRNGKey(1),
                      tm_cfg, epochs=3)
    acc = float(tm.accuracy(params, x[1000:1500], y[1000:1500], tm_cfg))
    print(f"single TM client accuracy: {acc:.3f}")

    conf = tm.confidence_scores(params, x[1500:1700], tm_cfg)
    print(f"per-class confidence: {conf.tolist()}")
    print(f"most-confident class (c_max): {int(jnp.argmax(conf))}")

    # --- 2. TPFL mini-federation (fully non-IID) ------------------------
    data = partition.partition(x, y, 10, n_clients=5, experiment=5,
                               key=jax.random.PRNGKey(2),
                               n_train=60, n_test=30, n_conf=30)
    fed_cfg = federation.FedConfig(n_clients=5, rounds=2, local_epochs=2)
    _, hist = federation.run(data, tm_cfg, fed_cfg, jax.random.PRNGKey(3))
    for r, h in enumerate(hist):
        print(f"round {r}: mean acc {float(h.mean_accuracy):.3f}  "
              f"clusters {h.assignment.tolist()}")
    up, down = federation.total_comm_mb(hist)
    print(f"total comm: upload {up*1000:.1f} KB, download {down*1000:.1f} KB"
          f"  (one class-weight vector per client per round)")


if __name__ == "__main__":
    main()
