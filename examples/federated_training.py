"""End-to-end driver: the paper's full experiment at laptop scale —
TPFL vs FedAvg vs FedTM on fully non-IID synthetic FEMNIST (62 classes),
multi-round, with exact communication metering.

  PYTHONPATH=src python examples/federated_training.py [--rounds 5]
"""
import argparse
import time

import jax

from repro.core import baselines, federation, tm
from repro.data import partition, synthetic


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=3)
    ap.add_argument("--clients", type=int, default=12)
    ap.add_argument("--dataset", default="synthfemnist",
                    choices=synthetic.DATASETS)
    args = ap.parse_args()

    key = jax.random.PRNGKey(0)
    x, y, dcfg = synthetic.make_dataset(args.dataset, 8000, key, side=12)
    data = partition.partition(
        x, y, dcfg.n_classes, n_clients=args.clients, experiment=5,
        key=jax.random.PRNGKey(1), n_train=80, n_test=40, n_conf=40)
    print(f"{args.dataset}: {dcfg.n_classes} classes, "
          f"{args.clients} clients, fully non-IID (experiment 5)")

    tm_cfg = tm.TMConfig(n_classes=dcfg.n_classes, n_clauses=48,
                         n_features=dcfg.n_features, s=5.0, T=40)

    t0 = time.time()
    fed_cfg = federation.FedConfig(n_clients=args.clients,
                                   rounds=args.rounds, local_epochs=2)
    _, hist = federation.run(data, tm_cfg, fed_cfg, jax.random.PRNGKey(2))
    up, down = federation.total_comm_mb(hist)
    print(f"\nTPFL   acc/round: "
          f"{[round(float(h.mean_accuracy), 3) for h in hist]}")
    print(f"TPFL   comm: up {up:.4f} MB / down {down:.4f} MB "
          f"({time.time()-t0:.0f}s)")

    bcfg = baselines.BaselineConfig(n_clients=args.clients,
                                    rounds=args.rounds, local_epochs=2)
    t0 = time.time()
    h = baselines.run_fedavg(data, bcfg, jax.random.PRNGKey(3),
                             dcfg.n_features, dcfg.n_classes)
    print(f"\nFedAvg acc/round: {[round(a, 3) for a in h.accuracy]}")
    print(f"FedAvg comm: up {h.upload_mb:.4f} MB ({time.time()-t0:.0f}s)")

    t0 = time.time()
    h = baselines.run_fedtm(data, tm_cfg, bcfg, jax.random.PRNGKey(4))
    print(f"\nFedTM  acc/round: {[round(a, 3) for a in h.accuracy]}")
    print(f"FedTM  comm: up {h.upload_mb:.4f} MB ({time.time()-t0:.0f}s)")

    print("\n→ TPFL uploads one class-weight vector per client-round; "
          "FedTM uploads all classes; FedAvg ships the full DL model.")


if __name__ == "__main__":
    main()
